"""TraceSanitizer — runtime validation of the orchestrator's decision stream.

The linter (:mod:`repro.analysis.lint`) catches nondeterminism *sources*; the
sanitizer catches *consequences*: it mirrors the control-plane state machine
off the same ``_note`` stream the decision-trace parity harness records, and
checks every transition against the invariants the orchestrator is supposed
to maintain.  Hooked in via ``OrchestratorConfig(sanitize=True)`` — on by
default in the parity tests and every bench ``--smoke`` — it validates:

* **monotone virtual time** — the heap never pops backwards (an event pushed
  into the past would);
* **version-stamped causality** — no stale worker event is ever applied to a
  lane (death/replan bumps ``lane.version``; the sanitizer proves the guard
  held), stale drops are counted;
* **worker liveness** — no dispatch, migrate-in, restore-in or admission onto
  a dead worker;
* **lane/slot conservation** — a trajectory is active on at most one worker,
  each worker holds at most ``max_active`` concurrent steps, and
  preempt/step events refer to actually-active trajectories;
* **migration commit/abort balance** — every launched transfer is exactly
  once committed (``migrate_done``) or aborted (checkpoint ``recover`` after
  the destination died); nothing is left on the wire at drain;
* **tenancy legality** — gold (tier-0) and non-sheddable trajectories are
  never shed; only non-gold work is degraded;
* **weight-epoch discipline** (async rollout-as-a-service) — a trajectory's
  ``weight_epoch`` stamp never changes mid-flight (a resident finishes on the
  policy that admitted it), each worker's applied epoch is strictly monotone,
  a sync only ever lands on an alive worker with zero resident lanes (the
  drain fence held), and a harvest fires exactly once, only after the
  trajectory finished.

Violations accumulate (capped) and :meth:`finalize` raises
:class:`TraceViolationError` listing them; ``report()`` returns counters plus
the sanitizer's own wall-clock cost so benches can publish the overhead.
"""

from __future__ import annotations

from time import perf_counter
from typing import Sequence

_MAX_RECORDED = 50  # keep the first N violations; count the rest
_EPS = 1e-9  # float-tolerant monotonicity


class TraceViolationError(AssertionError):
    """The decision stream broke a control-plane invariant."""

    def __init__(self, violations: Sequence[str], total: int):
        self.violations = list(violations)
        self.total = total
        shown = "\n  ".join(self.violations)
        extra = f" (+{total - len(self.violations)} more)" \
            if total > len(self.violations) else ""
        super().__init__(
            f"trace sanitizer: {total} invariant violation(s){extra}:\n  {shown}")


class TraceSanitizer:
    """Mirrors trajectory/worker lifecycle off the decision-note stream."""

    def __init__(self, trajectories, n_workers: int, max_active: int):
        self.max_active = max_active
        self.tenancy: dict[int, tuple[bool, int]] = {}
        self._trajs: dict[int, object] = {}
        self.register(trajectories)
        self.now = 0.0
        self.alive = [True] * n_workers
        self.active: list[set[int]] = [set() for _ in range(n_workers)]
        self.where: dict[int, int] = {}  # tid -> wid while a step is in progress
        self.finished: set[int] = set()
        self.shed: set[int] = set()
        self.pending_migration: dict[int, int] = {}  # tid -> dst on the wire
        self.pending_restore: dict[int, int] = {}  # tid -> dst re-admitting
        self.events = 0
        self.stale_worker_events = 0
        self.migrate_launches = 0
        self.migrate_commits = 0
        self.migrate_aborts = 0
        # async service plane: weight-epoch discipline + harvest bookkeeping
        self.worker_epoch = [0] * n_workers  # applied epoch, strictly monotone
        self.lane_epoch: dict[int, int] = {}  # tid -> stamp at first dispatch
        self.resident_of: dict[int, int] = {}  # tid -> admitting worker (serving)
        self.harvested: set[int] = set()
        self.weight_syncs = 0
        self.wall_s = 0.0
        self._violations: list[str] = []
        self._total_violations = 0

    def register(self, trajectories) -> None:
        """Adopt trajectories, including mid-run submissions (``inject``)."""
        for t in trajectories:
            self.tenancy[t.traj_id] = (bool(getattr(t, "sheddable", True)),
                                       int(getattr(t, "tenant_tier", 0)))
            self._trajs[t.traj_id] = t

    # ------------------------------------------------------------ plumbing
    def _flag(self, msg: str) -> None:
        self._total_violations += 1
        if len(self._violations) < _MAX_RECORDED:
            self._violations.append(f"t={self.now:.6f} {msg}")

    # ------------------------------------------------------------ hooks
    def on_clock(self, now: float) -> None:
        """Called once per heap pop, before the event is handled."""
        t0 = perf_counter()
        self.events += 1
        if now + _EPS < self.now:
            self._flag(f"virtual time went backwards: heap popped {now:.6f} "
                       f"after {self.now:.6f} (an event was pushed into the past)")
        else:
            self.now = now
        self.wall_s += perf_counter() - t0

    def on_worker_event(self, wid: int, applied: bool, lane_alive: bool) -> None:
        """Called for every popped worker event, stale or fresh."""
        t0 = perf_counter()
        if not applied:
            self.stale_worker_events += 1
        elif not lane_alive:
            self._flag(f"stale-guard breach: worker event applied to dead "
                       f"lane {wid} (death must bump lane.version)")
        self.wall_s += perf_counter() - t0

    def observe(self, kind: str, tid: int, wid: int) -> None:
        """One decision note, in emission order (same stream as the trace)."""
        t0 = perf_counter()
        handler = self._HANDLERS.get(kind)
        if handler is None:
            self._flag(f"unknown decision-note kind '{kind}': the sanitizer "
                       f"vocabulary must grow with the trace")
        else:
            handler(self, tid, wid)
        self.wall_s += perf_counter() - t0

    # ------------------------------------------------------------ note handlers
    def _not_terminal(self, tid: int, what: str) -> bool:
        if tid in self.finished:
            self._flag(f"{what} for trajectory {tid} after it finished")
            return False
        if tid in self.shed:
            self._flag(f"{what} for trajectory {tid} after it was shed")
            return False
        return True

    def _on_start(self, tid: int, wid: int) -> None:
        if not self.alive[wid]:
            self._flag(f"dispatch of trajectory {tid} onto dead worker {wid}")
        if tid in self.where:
            self._flag(f"trajectory {tid} dispatched on worker {wid} while "
                       f"still active on worker {self.where[tid]} "
                       f"(slot conservation)")
        if tid in self.pending_migration:
            self._flag(f"trajectory {tid} dispatched while its state is on "
                       f"the wire to worker {self.pending_migration[tid]}")
        self._not_terminal(tid, "dispatch")
        if len(self.active[wid]) >= self.max_active:
            self._flag(f"worker {wid} exceeds max_active={self.max_active} "
                       f"dispatching trajectory {tid} (slot conservation)")
        self.active[wid].add(tid)
        self.where[tid] = wid
        self._check_epoch(tid)

    def _check_epoch(self, tid: int) -> None:
        """Stamp immutability: a resident finishes on the policy that admitted
        it — its ``weight_epoch`` must never change while the lane lives."""
        traj = self._trajs.get(tid)
        if traj is None:
            return
        epoch = int(getattr(traj, "weight_epoch", 0))
        first = self.lane_epoch.setdefault(tid, epoch)
        if epoch != first:
            self._flag(f"trajectory {tid} weight epoch changed mid-flight "
                       f"({first} -> {epoch}): residents must finish on the "
                       f"policy that admitted them")

    def _on_preempt(self, tid: int, wid: int) -> None:
        if self.where.get(tid) != wid:
            self._flag(f"preemption of trajectory {tid} on worker {wid} but "
                       f"it is active on {self.where.get(tid)}")
        self.active[wid].discard(tid)
        self.where.pop(tid, None)

    def _on_step(self, tid: int, wid: int) -> None:
        if self.where.get(tid) != wid:
            self._flag(f"step completion for trajectory {tid} on worker {wid} "
                       f"but it is active on {self.where.get(tid)}")
        self.active[wid].discard(tid)
        self.where.pop(tid, None)
        self._check_epoch(tid)

    def _on_finish(self, tid: int, wid: int) -> None:
        if self._not_terminal(tid, "finish"):
            self.finished.add(tid)
        self._check_epoch(tid)
        self.resident_of.pop(tid, None)

    def _on_tool_done(self, tid: int, wid: int) -> None:
        self._not_terminal(tid, "tool completion")

    def _on_migrate(self, tid: int, dst: int) -> None:
        if not self.alive[dst]:
            self._flag(f"migration of trajectory {tid} launched toward dead "
                       f"worker {dst}")
        if tid in self.where:
            self._flag(f"migration of trajectory {tid} launched mid-step on "
                       f"worker {self.where[tid]} (only tool intervals "
                       f"migrate)")
        if tid in self.pending_migration:
            self._flag(f"second migration launched for trajectory {tid} while "
                       f"one is on the wire to {self.pending_migration[tid]}")
        self._not_terminal(tid, "migration launch")
        self.pending_migration[tid] = dst
        if tid in self.resident_of:  # residency rebinds to dst at launch
            self.resident_of[tid] = dst
        self.migrate_launches += 1

    def _on_migrate_done(self, tid: int, dst: int) -> None:
        src = self.pending_migration.pop(tid, None)
        if src is None:
            self._flag(f"migration commit for trajectory {tid} with no "
                       f"transfer on the wire (commit/abort balance)")
        elif src != dst:
            self._flag(f"migration of trajectory {tid} committed on worker "
                       f"{dst} but was launched toward {src}")
        if not self.alive[dst]:
            self._flag(f"migration of trajectory {tid} landed on dead "
                       f"worker {dst}")
        self.migrate_commits += 1

    def _on_recover(self, tid: int, dst: int) -> None:
        if not self.alive[dst]:
            self._flag(f"checkpoint recovery of trajectory {tid} onto dead "
                       f"worker {dst}")
        if tid in self.where:
            self._flag(f"recovery launched for trajectory {tid} while it is "
                       f"still active on worker {self.where[tid]}")
        self._not_terminal(tid, "recovery")
        if self.pending_migration.pop(tid, None) is not None:
            # in-flight transfer to a worker that died: the recovery aborts it
            self.migrate_aborts += 1
        if tid in self.resident_of:
            self.resident_of[tid] = dst
        self.pending_restore[tid] = dst  # re-route overwrites: token superseded

    def _on_restore_done(self, tid: int, wid: int) -> None:
        dst = self.pending_restore.pop(tid, None)
        if dst is None:
            self._flag(f"restore completion for trajectory {tid} with no "
                       f"restore in flight")
        elif dst != wid:
            self._flag(f"restore of trajectory {tid} landed on worker {wid} "
                       f"but was headed to {dst}")
        if not self.alive[wid]:
            self._flag(f"restore of trajectory {tid} landed on dead worker {wid}")

    def _on_worker_death(self, tid: int, wid: int) -> None:
        if not self.alive[wid]:
            self._flag(f"death event for worker {wid} which is already dead")
        self.alive[wid] = False
        for t in self.active[wid]:
            self.where.pop(t, None)
        self.active[wid].clear()

    def _on_worker_up(self, tid: int, wid: int) -> None:
        if self.alive[wid]:
            self._flag(f"revival event for worker {wid} which is already alive")
        self.alive[wid] = True

    def _on_arrival(self, tid: int, wid: int) -> None:
        self._not_terminal(tid, "arrival")

    def _on_admit(self, tid: int, wid: int) -> None:
        if 0 <= wid < len(self.alive) and not self.alive[wid]:
            self._flag(f"trajectory {tid} admitted onto dead worker {wid}")
        self._not_terminal(tid, "admission")
        if 0 <= wid < len(self.alive):
            self.resident_of[tid] = wid

    def _on_defer(self, tid: int, wid: int) -> None:
        self._not_terminal(tid, "deferral")

    def _on_shed(self, tid: int, wid: int) -> None:
        sheddable, tier = self.tenancy.get(tid, (True, 0))
        if tier == 0:
            self._flag(f"gold-tier trajectory {tid} was shed (tenancy "
                       f"legality: gold is never shed)")
        if not sheddable:
            self._flag(f"non-sheddable trajectory {tid} was shed")
        if tid in self.where:
            self._flag(f"trajectory {tid} shed while actively generating on "
                       f"worker {self.where[tid]} (only queued work sheds)")
        if self._not_terminal(tid, "shed"):
            self.shed.add(tid)
        self.resident_of.pop(tid, None)

    def _on_harvest(self, tid: int, wid: int) -> None:
        if tid not in self.finished:
            self._flag(f"harvest of trajectory {tid} before it finished "
                       f"(the consumer would train on a partial episode)")
        if tid in self.harvested:
            self._flag(f"trajectory {tid} harvested twice (duplicate sample)")
        self.harvested.add(tid)

    def _on_weight_sync(self, epoch: int, wid: int) -> None:
        """The note's tid slot carries the applied epoch, not a trajectory."""
        if not self.alive[wid]:
            self._flag(f"weight sync applied to dead worker {wid}")
        if self.active[wid]:
            self._flag(f"weight sync on worker {wid} with steps in progress "
                       f"{sorted(self.active[wid])}: the drain fence leaked")
        held = sorted(t for t, w in self.resident_of.items() if w == wid)
        if held:
            self._flag(f"weight sync on worker {wid} holding resident "
                       f"trajectories {held}: the drain fence leaked")
        if epoch <= self.worker_epoch[wid]:
            self._flag(f"worker {wid} applied weight epoch went backwards "
                       f"({self.worker_epoch[wid]} -> {epoch}): applied "
                       f"epochs must be strictly monotone")
        self.worker_epoch[wid] = epoch
        self.weight_syncs += 1

    def _on_degrade(self, tid: int, wid: int) -> None:
        _, tier = self.tenancy.get(tid, (True, 0))
        if tier == 0:
            self._flag(f"gold-tier trajectory {tid} was degraded (the ladder "
                       f"must not touch gold)")
        self._not_terminal(tid, "degradation")

    _HANDLERS = {
        "start": _on_start,
        "preempt": _on_preempt,
        "step": _on_step,
        "finish": _on_finish,
        "tool_done": _on_tool_done,
        "migrate": _on_migrate,
        "migrate_done": _on_migrate_done,
        "recover": _on_recover,
        "restore_done": _on_restore_done,
        "worker_death": _on_worker_death,
        "worker_up": _on_worker_up,
        "arrival": _on_arrival,
        "admit": _on_admit,
        "defer": _on_defer,
        "shed": _on_shed,
        "degrade": _on_degrade,
        "harvest": _on_harvest,
        "weight_sync": _on_weight_sync,
    }

    # ------------------------------------------------------------ teardown
    def finalize(self, strict: bool = True) -> dict:
        """End-of-run balance checks; raises on any accumulated violation."""
        t0 = perf_counter()
        for tid, dst in sorted(self.pending_migration.items()):
            self._flag(f"trajectory {tid} still on the wire to worker {dst} "
                       f"at drain (migration commit/abort imbalance)")
        for tid, dst in sorted(self.pending_restore.items()):
            self._flag(f"trajectory {tid} still restoring onto worker {dst} "
                       f"at drain")
        for wid, acts in enumerate(self.active):
            if acts:
                self._flag(f"worker {wid} drained with active trajectories "
                           f"{sorted(acts)} (slot leak)")
        self.wall_s += perf_counter() - t0
        if strict and self._total_violations:
            raise TraceViolationError(self._violations, self._total_violations)
        return self.report()

    def report(self) -> dict:
        return {
            "events": self.events,
            "violations": self._total_violations,
            "stale_worker_events": self.stale_worker_events,
            "migrations": {
                "launched": self.migrate_launches,
                "committed": self.migrate_commits,
                "aborted": self.migrate_aborts,
            },
            "harvests": len(self.harvested),
            "weight_syncs": self.weight_syncs,
            "wall_s": self.wall_s,
        }


def check_block_conservation(worker_stats: dict) -> list[str]:
    """Paged-pool drain check: every block reference must be accounted for.

    Consumes the ``blocks_*`` occupancy counters paged engines merge into
    ``dispatch_stats()`` (workers without them — dense fallback, sim — are
    skipped) and enforces, per worker:

    * ``allocated_total - freed_total == resident + shared`` — cumulative
      reference increments minus decrements equals live references (a
      mismatch is a leaked or double-freed block);
    * ``total == free + resident`` — distinct blocks partition exactly into
      the free heap and the resident set.

    Returns violation strings (empty = conserved); the runtime raises
    :class:`TraceViolationError` on any when ``sanitize`` is on.
    """
    out: list[str] = []
    for wid in sorted(worker_stats):
        s = worker_stats[wid]
        if "blocks_allocated_total" not in s:
            continue
        live = s["blocks_allocated_total"] - s["blocks_freed_total"]
        held = s["blocks_resident"] + s["blocks_shared"]
        if live != held:
            out.append(
                f"worker {wid}: block-reference leak — allocated "
                f"{s['blocks_allocated_total']} - freed "
                f"{s['blocks_freed_total']} = {live} live refs, but resident "
                f"{s['blocks_resident']} + shared {s['blocks_shared']} = {held}")
        if s["blocks_total"] != s["blocks_free"] + s["blocks_resident"]:
            out.append(
                f"worker {wid}: block partition broken — total "
                f"{s['blocks_total']} != free {s['blocks_free']} + resident "
                f"{s['blocks_resident']}")
    return out


__all__ = ["TraceSanitizer", "TraceViolationError", "check_block_conservation"]
