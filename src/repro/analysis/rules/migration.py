"""HDL005 — no host-gather of KV buffers on migration/checkpoint paths.

The paged data plane moves KV between engines as device-to-device block
copies of *resident* pages (``worker._ingest_pages`` / ``model
.paged_gather_pages``).  A ``np.asarray`` / ``np.array`` / ``jax.device_get``
of cache pages inside a ``migrate*`` / ``checkpoint*`` / ``restore*``
function round-trips the whole payload through host memory — the exact
bounce the paged pool exists to eliminate, and it serializes the device
against the host for the full transfer.

Legitimate host bounces carry a noqa with the reason: a tool-boundary
checkpoint must outlive its source device; the dense fallback pool and the
legacy lane engine have no page tables to D2D-copy.

The rule only fires when the gathered expression references a KV-ish name
(``cache`` / ``page`` / ``kv`` / ``lane`` / ``pool`` / ``block``) — small
metadata like RNG keys or slot indices host-gather freely.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, Optional

from repro.analysis.rules.base import FileContext, Scope, Violation, dotted_name

#: functions that form the KV transfer family
_MIG_FN = re.compile(r"(^|_)(migrate|checkpoint|restore)", re.I)

#: host-gathering callables (resolved dotted paths)
_SYNC_PATHS = {"numpy.asarray", "numpy.array", "jax.device_get"}

#: tree-mapping callables whose mapped fn may be a host gather
_TREE_MAPS = {"jax.tree.map", "jax.tree_map", "jax.tree_util.tree_map"}

#: identifier fragments that mark an expression as KV-cache data
_KV_HINTS = ("cache", "page", "kv", "lane", "pool", "block")


def _mentions_kv(node: ast.AST) -> bool:
    """True if any identifier / attribute / string key in ``node`` looks KV-ish."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            text = sub.id
        elif isinstance(sub, ast.Attribute):
            text = sub.attr
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            text = sub.value
        else:
            continue
        low = text.lower()
        if any(h in low for h in _KV_HINTS):
            return True
    return False


class RuleHDL005:
    """Migration/checkpoint paths must move KV device-to-device, not via host."""

    rule_id = "HDL005"
    scope = Scope.NONE  # anywhere an engine moves KV

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _MIG_FN.search(node.name):
                continue
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                hit = self._host_gather(call, ctx)
                if hit is None:
                    continue
                spelled, payload = hit
                if not _mentions_kv(payload):
                    continue  # keys / slot indices / metadata: fine to gather
                yield Violation(
                    self.rule_id, ctx.path, call.lineno, call.col_offset,
                    f"`{spelled}` host-gathers a KV buffer inside "
                    f"`{node.name}`: same-process moves must D2D-copy "
                    f"resident pages (paged_gather_pages/_ingest_pages); "
                    f"justify a durability or dense-fallback bounce with "
                    f"a noqa")

    @staticmethod
    def _host_gather(call: ast.Call,
                     ctx: FileContext) -> Optional[tuple[str, ast.AST]]:
        """(spelling, gathered expression) when ``call`` host-gathers."""
        target = ctx.imports.resolve(call.func)
        if target in _SYNC_PATHS and call.args:
            return f"{dotted_name(call.func)}(...)", call.args[0]
        # jax.tree.map(np.asarray, tree): the gather hides in the mapped fn
        if target in _TREE_MAPS and len(call.args) >= 2:
            fn = ctx.imports.resolve(call.args[0])
            if fn in _SYNC_PATHS:
                return (f"{dotted_name(call.func)}({dotted_name(call.args[0])},"
                        f" ...)", call.args[1])
        return None


__all__ = ["RuleHDL005"]
