"""HDL003 — jit-cache hygiene and host-sync discipline.

Two failure modes this rule pins down:

1. **Retrace leaks.** ``jax.jit``/``pjit`` caches compiled executables keyed
   on the *static* arguments and the avals of the traced ones.  Passing the
   mesh or a config object as a traced argument either fails outright
   (unhashable pytree leaves) or — worse — silently retraces per call when
   the object is hashable but fresh each time.  Every jit site whose wrapped
   function takes a ``mesh``/``cfg``/``config`` parameter must name it in
   ``static_argnames``/``static_argnums``.

2. **Decode-loop host syncs.** A ``.item()``/``np.asarray``/``device_get``
   inside the per-token/per-chunk loop of a decode or prefill path serializes
   the host against the accelerator once per iteration — the classic
   dispatch-pipeline stall.  Device values must stay on device until the loop
   exits (or the sync must be justified with a noqa, e.g. a deliberate
   early-exit check).
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, Optional

from repro.analysis.rules.base import FileContext, Scope, Violation, dotted_name

#: parameters that must be static at any jit site that accepts them
_STATIC_REQUIRED = {"mesh", "cfg", "config"}

#: function names whose loop bodies are token/chunk hot paths
_HOT_FN = re.compile(r"(^|_)(decode|prefill|extend)", re.I)

#: host-synchronizing callables (by resolved dotted path or attribute name)
_SYNC_PATHS = {"numpy.asarray", "numpy.array", "jax.device_get"}
_SYNC_ATTRS = {"item", "block_until_ready", "tolist"}


def _jit_static(dec: ast.AST, imports) -> Optional[tuple[set[str], set[int]]]:
    """If ``dec`` is a jit/pjit decoration, return its (static names, nums)."""
    # bare @jax.jit / @pjit
    target = imports.resolve(dec)
    if target in ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"):
        return set(), set()
    if not isinstance(dec, ast.Call):
        return None
    # @jax.jit(...) / @partial(jax.jit, ...) / @functools.partial(jax.jit, ...)
    fn = imports.resolve(dec.func)
    if fn in ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"):
        call = dec
    elif fn in ("functools.partial", "partial") and dec.args and \
            imports.resolve(dec.args[0]) in ("jax.jit", "jax.pjit",
                                             "jax.experimental.pjit.pjit"):
        call = dec
    else:
        return None
    names: set[str] = set()
    nums: set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
        elif kw.arg == "static_argnums":
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    nums.add(el.value)
    return names, nums


class RuleHDL003:
    """jit sites must pin mesh/config static; decode loops must not host-sync."""

    rule_id = "HDL003"
    scope = Scope.NONE  # anywhere jax shows up

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        yield from self._check_jit_sites(ctx)
        yield from self._check_hot_loops(ctx)

    # -------------------------------------------------- retrace leaks
    def _check_jit_sites(self, ctx: FileContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    static = _jit_static(dec, ctx.imports)
                    if static is not None:
                        yield from self._audit(node, static, ctx, dec.lineno,
                                               dec.col_offset)
            elif isinstance(node, ast.Call):
                # inline jit(fn, ...) where fn is a lambda or a local def we
                # can see the parameters of
                target = ctx.imports.resolve(node.func)
                if target not in ("jax.jit", "jax.pjit",
                                  "jax.experimental.pjit.pjit"):
                    continue
                static = _jit_static(node, ctx.imports) or (set(), set())
                if node.args and isinstance(node.args[0], ast.Lambda):
                    yield from self._audit(node.args[0], static, ctx,
                                           node.lineno, node.col_offset)

    def _audit(self, fn, static: tuple[set[str], set[int]], ctx: FileContext,
               line: int, col: int) -> Iterator[Violation]:
        names, nums = static
        params = [a.arg for a in fn.args.args]
        for idx, p in enumerate(params):
            if p in _STATIC_REQUIRED and p not in names and idx not in nums:
                yield Violation(
                    self.rule_id, ctx.path, line, col,
                    f"jit site traces parameter `{p}`: meshes/configs must be "
                    f"listed in static_argnames/static_argnums or the cache "
                    f"retraces (or fails) per call")

    # -------------------------------------------------- decode-loop syncs
    def _check_hot_loops(self, ctx: FileContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _HOT_FN.search(node.name):
                continue
            for loop in ast.walk(node):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for call in ast.walk(loop):
                    if not isinstance(call, ast.Call):
                        continue
                    msg = self._sync_call(call, ctx)
                    if msg is not None:
                        yield Violation(self.rule_id, ctx.path, call.lineno,
                                        call.col_offset,
                                        f"{msg} inside the `{node.name}` "
                                        f"loop forces a device→host sync per "
                                        f"iteration; hoist it out of the "
                                        f"loop or justify with a noqa")

    @staticmethod
    def _sync_call(call: ast.Call, ctx: FileContext) -> Optional[str]:
        target = ctx.imports.resolve(call.func)
        if target in _SYNC_PATHS:
            return f"`{dotted_name(call.func)}(...)`"
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in _SYNC_ATTRS and not call.args:
            return f"`.{call.func.attr}()`"
        return None


__all__ = ["RuleHDL003"]
