"""Shared infrastructure for heddle lint rules: violations, file context,
import-alias resolution.

Rules operate on a :class:`FileContext` — one parsed module plus the scope
tags the lint driver derived from its path (see :data:`Scope`).  The
:class:`ImportMap` resolves attribute chains like ``np.random.default_rng``
back to canonical dotted module paths (``numpy.random.default_rng``) so rules
match semantics, not surface spelling.
"""

from __future__ import annotations

import ast
import enum
from dataclasses import dataclass, field
from typing import Iterator, Optional, Protocol


class Scope(enum.Flag):
    """Where a file sits in the codebase; rules opt into scopes.

    CONTROL covers the decision-making planes (core/, engine/, rl/) where
    determinism rules apply.  CORE narrows to core/ alone — the virtual-time
    control plane where even ``time.perf_counter`` wall telemetry is banned
    (the engine legitimately measures wall time; core must never see it).
    """

    NONE = 0
    CONTROL = enum.auto()
    CORE = enum.auto()


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}"


@dataclass
class FileContext:
    """One module as the rules see it."""

    path: str  # display path (repo-relative when possible)
    source: str
    tree: ast.Module
    scope: Scope
    lines: list[str] = field(default_factory=list)
    imports: "ImportMap" = None  # type: ignore[assignment]

    def __post_init__(self):
        if not self.lines:
            self.lines = self.source.splitlines()
        if self.imports is None:
            self.imports = ImportMap.from_tree(self.tree)


class Rule(Protocol):
    rule_id: str
    scope: Scope  # Scope.NONE means "applies everywhere"

    def check(self, ctx: FileContext) -> Iterator[Violation]: ...


class ImportMap:
    """Alias table mapping local names to canonical dotted import paths."""

    def __init__(self, aliases: dict[str, str]):
        self.aliases = aliases

    @classmethod
    def from_tree(cls, tree: ast.Module) -> "ImportMap":
        aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    if a.name != "*":
                        aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        # common conventions even when the import is elided/lazy
        aliases.setdefault("np", "numpy")
        aliases.setdefault("jnp", "jax.numpy")
        return cls(aliases)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted path for a Name/Attribute chain, else None."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])


def dotted_name(node: ast.AST) -> Optional[str]:
    """Surface spelling of a Name/Attribute chain (no alias resolution)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))
