"""HDL001/HDL002 — control-plane determinism rules.

The decision-trace parity harness (tests/test_orchestrator.py) proves the
sim and engine backends make bit-identical scheduling decisions.  That proof
only holds while the control plane draws on no ambient nondeterminism: no
wall clock, no process-seeded RNG, no iteration order that CPython does not
guarantee.  These two rules mechanize that contract.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.rules.base import FileContext, ImportMap, Scope, Violation

# ---------------------------------------------------------------- HDL001

# ambient wall clocks: any read makes a decision depend on the host
_WALL_CLOCK = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.ctime",
    "time.localtime",
    "time.gmtime",
}
# wall telemetry: legal in the engine (measured stats), banned in core/
# where every timestamp must be virtual
_WALL_TELEMETRY = {"time.perf_counter", "time.perf_counter_ns", "time.process_time"}
_DATETIME_NOW = {"now", "utcnow", "today"}
# numpy.random attrs that construct *explicitly seeded* generators (legal);
# everything else on numpy.random touches the hidden global state
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
                 "MT19937", "BitGenerator", "RandomState"}
# random-module attrs that construct a seedable instance (legal)
_PY_RANDOM_OK = {"Random"}


class RuleHDL001:
    """No wall-clock or unseeded-RNG calls in control-plane modules."""

    rule_id = "HDL001"
    scope = Scope.CONTROL

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.imports.resolve(node.func)
            if target is None:
                continue
            msg = self._classify(target, ctx.scope)
            if msg is not None:
                yield Violation(self.rule_id, ctx.path, node.lineno,
                                node.col_offset, msg)

    @staticmethod
    def _classify(target: str, scope: Scope) -> Optional[str]:
        if target in _WALL_CLOCK:
            return (f"wall-clock read `{target}()` in a control-plane module: "
                    f"decisions must depend only on virtual time")
        if target in _WALL_TELEMETRY and scope & Scope.CORE:
            return (f"`{target}()` in repro/core: wall telemetry is an engine "
                    f"concern; core sees only virtual time")
        last = target.rsplit(".", 1)[-1]
        if target.startswith("datetime.") and last in _DATETIME_NOW:
            return (f"`{target}()` reads the wall clock; control-plane "
                    f"decisions must depend only on virtual time")
        if target.startswith("numpy.random.") and last not in _NP_RANDOM_OK:
            return (f"`{target}()` uses numpy's hidden global RNG; construct "
                    f"an explicit `numpy.random.default_rng(seed)` instead")
        if target.startswith("random.") and last not in _PY_RANDOM_OK:
            return (f"`{target}()` uses the process-global `random` state; "
                    f"use an explicitly seeded `random.Random(seed)` or a "
                    f"numpy Generator")
        return None


# ---------------------------------------------------------------- HDL002

_SET_ANNOTATIONS = {"set", "Set", "frozenset", "FrozenSet", "MutableSet",
                    "AbstractSet"}


def _annotation_is_set(node: ast.AST) -> bool:
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        base = node.value.split("[", 1)[0].strip()
        return base.rsplit(".", 1)[-1] in _SET_ANNOTATIONS
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return name in _SET_ANNOTATIONS


def _value_is_set(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


_SCOPE_BARRIERS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)


def _scope_nodes(body) -> Iterator[ast.AST]:
    """Yield nodes of one lexical scope without descending into nested defs."""
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _SCOPE_BARRIERS):
            stack.extend(ast.iter_child_nodes(node))


class _SetNames:
    """Inventory of set-typed names: per-scope locals + module-wide attributes.

    Locals are tracked per function scope (a name that is a set in one
    function does not taint a same-named Sequence parameter elsewhere).
    Attribute matching is by name only (any ``x.active`` matches a module
    that declares ``self.active: set[int]`` somewhere) — deliberately
    over-approximate: a decision loop over *any* unordered collection in a
    control-plane module deserves a look, and ``sorted(...)`` or a noqa with
    justification resolves the finding either way.
    """

    def __init__(self, tree: ast.Module):
        self.attrs: set[str] = set()
        self.module_names: set[str] = set()
        self._locals: set[str] = set()  # active function scope, set per check
        for node in ast.walk(tree):
            # instance/class attributes are module-wide by attr name
            if isinstance(node, ast.AnnAssign) and _annotation_is_set(node.annotation) \
                    and isinstance(node.target, ast.Attribute):
                self.attrs.add(node.target.attr)
            elif isinstance(node, ast.Assign) and _value_is_set(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        self.attrs.add(t.attr)
            elif isinstance(node, ast.ClassDef):
                for sub in _scope_nodes(node.body):
                    if isinstance(sub, ast.AnnAssign) \
                            and _annotation_is_set(sub.annotation) \
                            and isinstance(sub.target, ast.Name):
                        self.attrs.add(sub.target.id)
        self.module_names = self._scope_locals(tree.body)

    @staticmethod
    def _scope_locals(body) -> set[str]:
        names: set[str] = set()
        for node in _scope_nodes(body):
            if isinstance(node, ast.AnnAssign) and _annotation_is_set(node.annotation) \
                    and isinstance(node.target, ast.Name):
                names.add(node.target.id)
            elif isinstance(node, ast.Assign) and _value_is_set(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
        return names

    def enter_scope(self, fn) -> None:
        if fn is None:
            self._locals = set()
            return
        self._locals = self._scope_locals(fn.body)
        # parameters annotated as sets are set-typed for this scope
        a = fn.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            if arg.annotation is not None and _annotation_is_set(arg.annotation):
                self._locals.add(arg.arg)

    def is_set_expr(self, node: ast.AST) -> bool:
        if _value_is_set(node):
            return True
        if isinstance(node, ast.Name):
            return node.id in self._locals or node.id in self.module_names
        if isinstance(node, ast.Attribute):
            return node.attr in self.attrs
        if isinstance(node, ast.Call):
            # list(s) / tuple(s) / iter(s) preserve the unordered traversal
            if isinstance(node.func, ast.Name) and node.func.id in (
                    "list", "tuple", "iter", "enumerate", "reversed") and node.args:
                return self.is_set_expr(node.args[0])
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                    "union", "intersection", "difference", "symmetric_difference",
                    "copy") and self.is_set_expr(node.func.value):
                return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self.is_set_expr(node.left) or self.is_set_expr(node.right)
        return False


class RuleHDL002:
    """No iteration over a set (or ``dict.keys()``) in control-plane loops.

    ``for x in some_set`` traverses in hash order — stable within one process
    for int keys, but an implementation detail, and instantly divergent the
    moment ids become strings or the insert/delete history differs between
    backends.  Any such loop that feeds scheduling, placement, shedding or
    event emission silently breaks decision-trace parity.  Wrap the iterable
    in ``sorted(...)`` (canonical order) or suppress with a justification.
    ``dict.keys()`` is flagged in the same position: control-plane convention
    is explicit ``sorted(...)`` order at decision sites, and a bare
    ``.keys()`` loop is where unordered rewrites creep in.
    """

    rule_id = "HDL002"
    scope = Scope.CONTROL

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        inventory = _SetNames(ctx.tree)
        scopes: list = [None]  # module scope first, then each function
        scopes.extend(n for n in ast.walk(ctx.tree)
                      if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)))
        for fn in scopes:
            inventory.enter_scope(fn)
            body = ctx.tree.body if fn is None else fn.body
            for node in _scope_nodes(body):
                iters: list[ast.AST] = []
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    iters.append(node.iter)
                elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                       ast.GeneratorExp)):
                    iters.extend(gen.iter for gen in node.generators)
                for it in iters:
                    v = self._inspect(it, inventory, ctx)
                    if v is not None:
                        yield v

    def _inspect(self, it: ast.AST, inv: _SetNames,
                 ctx: FileContext) -> Optional[Violation]:
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute) \
                and it.func.attr == "keys" and not it.args:
            return Violation(
                self.rule_id, ctx.path, it.lineno, it.col_offset,
                "iteration over `.keys()` in a control-plane loop: iterate "
                "`sorted(d)` at decision sites (or the dict itself for "
                "order-insensitive reads)")
        if inv.is_set_expr(it):
            return Violation(
                self.rule_id, ctx.path, it.lineno, it.col_offset,
                "iteration over a set in a control-plane loop traverses in "
                "hash order; wrap in `sorted(...)` so the decision sequence "
                "is canonical")
        return None


__all__ = ["RuleHDL001", "RuleHDL002", "ImportMap"]
