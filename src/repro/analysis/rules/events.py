"""HDL004 — event-heap discipline.

The orchestrator's versioned heap is the only channel control-plane causality
flows through; PRs 5–7 each added event kinds (worker, tool_done,
migration_done, restore_done, arrival, worker_death, worker_up) and each new
kind needed both a handler branch *and* a staleness guard.  This rule keeps
the three legs aligned inside any module that pushes events:

* every kind pushed via ``self._push(t, "kind", payload)`` has a matching
  ``kind == "kind"`` handler comparison (no silently dropped events);
* every handled kind is actually pushed somewhere (no dead branches masking
  a renamed event);
* every *tuple* payload carries a version/token stamp — a field whose name
  contains ``version``/``token``/``ver``/``seq`` — so the handler can reject
  stale deliveries.  Scalar payloads (a bare traj/worker id) are exempt:
  they identify an entity whose handler re-validates against live state.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.rules.base import FileContext, Scope, Violation

_STAMP_MARKERS = ("version", "token", "ver", "seq")


def _push_kind(call: ast.Call) -> Optional[tuple[str, Optional[ast.AST]]]:
    """Match ``self._push(t, "kind", payload)``; return (kind, payload)."""
    fn = call.func
    if not (isinstance(fn, ast.Attribute) and fn.attr == "_push"):
        return None
    if len(call.args) < 2:
        return None
    kind = call.args[1]
    if not (isinstance(kind, ast.Constant) and isinstance(kind.value, str)):
        return None
    payload = call.args[2] if len(call.args) > 2 else None
    return kind.value, payload


def _handled_kinds(tree: ast.Module) -> dict[str, int]:
    """kind -> first line of a ``kind == "..."`` / ``kind in (...)`` test."""
    handled: dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        if not (isinstance(node.left, ast.Name) and node.left.id == "kind"):
            continue
        cmp = node.comparators[0]
        if isinstance(node.ops[0], ast.Eq) and isinstance(cmp, ast.Constant) \
                and isinstance(cmp.value, str):
            handled.setdefault(cmp.value, node.lineno)
        elif isinstance(node.ops[0], ast.In):
            for el in ast.walk(cmp):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    handled.setdefault(el.value, node.lineno)
    return handled


def _tuple_has_stamp(payload: ast.Tuple) -> bool:
    for el in payload.elts:
        for sub in ast.walk(el):
            name = None
            if isinstance(sub, ast.Name):
                name = sub.id
            elif isinstance(sub, ast.Attribute):
                name = sub.attr
            elif isinstance(sub, ast.Call):
                # next(self._xfer_seq)-style freshly minted tokens
                continue
            if name and any(m in name.lower() for m in _STAMP_MARKERS):
                return True
    return False


class RuleHDL004:
    """Pushed event kinds ↔ handler branches ↔ version-stamped payloads."""

    rule_id = "HDL004"
    scope = Scope.NONE  # applies to any module that pushes heap events

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        pushes: list[tuple[str, Optional[ast.AST], int, int]] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                m = _push_kind(node)
                if m is not None:
                    pushes.append((m[0], m[1], node.lineno, node.col_offset))
        if not pushes:
            return
        handled = _handled_kinds(ctx.tree)
        if not handled:
            # pushes but no dispatcher in this module: cross-module event flow
            # is out of scope for a per-file rule
            return
        pushed_kinds = {k for k, _, _, _ in pushes}
        for kind, payload, line, col in pushes:
            if kind not in handled:
                yield Violation(
                    self.rule_id, ctx.path, line, col,
                    f"event kind '{kind}' is pushed onto the heap but has no "
                    f"`kind == \"{kind}\"` handler branch: the event would be "
                    f"popped and dropped silently")
            if isinstance(payload, ast.Tuple) and not _tuple_has_stamp(payload):
                yield Violation(
                    self.rule_id, ctx.path, line, col,
                    f"event kind '{kind}' carries a multi-field payload with "
                    f"no version/token stamp: the handler cannot reject a "
                    f"stale delivery (add a lane.version / transfer token "
                    f"field)")
        for kind, line in sorted(handled.items()):
            if kind not in pushed_kinds:
                yield Violation(
                    self.rule_id, ctx.path, line, 0,
                    f"handler branch for event kind '{kind}' but nothing in "
                    f"this module pushes it: dead branch, or the emission was "
                    f"renamed without its handler")


__all__ = ["RuleHDL004"]
