"""Rule registry for the heddle linter.

Each rule is a callable ``check(ctx) -> Iterator[Violation]`` over a parsed
module (:class:`repro.analysis.lint.FileContext`).  Rules are registered by
id in :data:`ALL_RULES`; :mod:`repro.analysis.lint` applies every rule whose
scope matches the file being linted and filters ``# heddle: noqa`` lines.

To add a rule: implement it in a module here, give it a unique ``HDLxxx`` id,
add it to :data:`ALL_RULES`, document it in docs/analysis.md, and add
positive/negative fixtures under tests/fixtures/lint/.
"""

from __future__ import annotations

from repro.analysis.rules.determinism import RuleHDL001, RuleHDL002
from repro.analysis.rules.events import RuleHDL004
from repro.analysis.rules.jit_hygiene import RuleHDL003
from repro.analysis.rules.migration import RuleHDL005

#: all registered rules, keyed by id, in catalog order
ALL_RULES = {
    "HDL001": RuleHDL001(),
    "HDL002": RuleHDL002(),
    "HDL003": RuleHDL003(),
    "HDL004": RuleHDL004(),
    "HDL005": RuleHDL005(),
}

__all__ = ["ALL_RULES", "RuleHDL001", "RuleHDL002", "RuleHDL003", "RuleHDL004",
           "RuleHDL005"]
