"""ExecutionBackend conformance checker.

``repro.core.orchestrator.ExecutionBackend`` is a ``typing.Protocol``: it is
never instantiated, so nothing at runtime forces SimBackend and EngineBackend
to keep matching it — a renamed parameter or a dropped method surfaces only
as a confusing orchestrator crash (or worse, a silent behavioural fork
between the backends the parity harness then chases for hours).  This module
diffs a backend class against the protocol **statically**:

* **method set** — every protocol method exists and is callable; the
  ``interruptible`` attribute and ``n_workers`` property are present (class
  attribute, property, or an ``__init__`` assignment found by AST);
* **signatures** — positional parameter names *and order* match the protocol
  exactly (the orchestrator calls positionally); extra backend-specific
  parameters are allowed only when they carry defaults; a default the
  protocol declares (e.g. ``admit(..., now=0.0)``) may not be dropped;
* **return contract** — when the backend annotates a return type it must
  match the protocol's (modulo the compatibility table below, e.g. ``list``
  satisfies ``Iterable``); an unannotated override must at least carry a
  docstring so the return shape is documented somewhere.

Run ``python -m repro.analysis.protocol`` to check the two shipped backends;
``check_backend(cls)`` returns a list of human-readable drift findings
(empty = conformant) for use from tests and CI.
"""

from __future__ import annotations

import ast
import inspect
import sys
import textwrap
from typing import Optional, Sequence, get_type_hints

#: return-annotation compatibility: protocol annotation -> accepted backend
#: annotations (string-normalized).  Anything not listed must match exactly.
_RETURN_COMPAT = {
    "Iterable[int]": {"Iterable[int]", "list[int]", "tuple[int, ...]",
                      "Sequence[int]"},
    "Optional[float]": {"Optional[float]", "float | None", "None | float"},
}


def _norm_annotation(ann) -> Optional[str]:
    if ann is inspect.Signature.empty:
        return None
    if isinstance(ann, str):
        s = ann
    else:
        s = getattr(ann, "__name__", None) or str(ann)
    for junk in ("typing.", "builtins."):
        s = s.replace(junk, "")
    return s.replace(" ", "").replace("'", "")


def _compatible_return(proto: Optional[str], impl: Optional[str]) -> bool:
    if proto is None or impl is None:
        return True  # nothing to diff
    if proto == impl:
        return True
    accepted = _RETURN_COMPAT.get(proto.replace(",...]", ", ...]"), set())
    return impl in {_norm_annotation(a) for a in accepted} | accepted


def _init_assigns_attr(cls: type, attr: str) -> bool:
    """AST check: does any method in ``cls`` (or a base) assign ``self.attr``?

    Backends set ``interruptible`` in ``__init__`` rather than as a class
    attribute (it can depend on construction arguments), so a pure
    ``hasattr`` on the class misses it.
    """
    for klass in cls.__mro__:
        if klass is object:
            continue
        try:
            src = textwrap.dedent(inspect.getsource(klass))
        except (OSError, TypeError):
            continue
        for node in ast.walk(ast.parse(src)):
            targets: list[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if (isinstance(t, ast.Attribute) and t.attr == attr
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    return True
    return False


def _protocol_methods(protocol: type) -> dict[str, inspect.Signature]:
    out: dict[str, inspect.Signature] = {}
    for name, member in vars(protocol).items():
        if name.startswith("_"):
            continue
        if isinstance(member, property):
            continue  # properties checked separately
        if callable(member):
            out[name] = inspect.signature(member)
    return out


def _protocol_properties(protocol: type) -> list[str]:
    return [n for n, m in vars(protocol).items()
            if isinstance(m, property) and not n.startswith("_")]


def _protocol_attrs(protocol: type) -> list[str]:
    return [n for n in getattr(protocol, "__annotations__", {})
            if not n.startswith("_")]


def check_backend(cls: type, protocol: Optional[type] = None) -> list[str]:
    """Diff ``cls`` against the ExecutionBackend protocol; [] = conformant."""
    if protocol is None:
        from repro.core.orchestrator import ExecutionBackend as protocol  # noqa: N813
    findings: list[str] = []
    who = cls.__name__

    for attr in _protocol_attrs(protocol):
        if not (hasattr(cls, attr) or _init_assigns_attr(cls, attr)):
            findings.append(f"{who}: missing attribute `{attr}` (declared on "
                            f"the protocol; set it in __init__ or on the class)")

    for prop in _protocol_properties(protocol):
        member = inspect.getattr_static(cls, prop, None)
        if member is None:
            findings.append(f"{who}: missing property `{prop}`")
        elif not isinstance(member, property) and not callable(member):
            findings.append(f"{who}: `{prop}` must be a property or method, "
                            f"found {type(member).__name__}")

    try:
        proto_hints = get_type_hints(protocol)  # noqa: F841  (resolves lazily)
    except Exception:
        pass

    for name, proto_sig in _protocol_methods(protocol).items():
        impl = inspect.getattr_static(cls, name, None)
        if impl is None:
            findings.append(f"{who}: missing method `{name}`")
            continue
        impl_fn = impl.__func__ if isinstance(impl, (staticmethod, classmethod)) \
            else impl
        if not callable(impl_fn):
            findings.append(f"{who}: `{name}` is not callable")
            continue
        try:
            impl_sig = inspect.signature(impl_fn)
        except (TypeError, ValueError):
            continue
        findings.extend(_diff_signature(who, name, proto_sig, impl_sig))
        proto_ret = _norm_annotation(proto_sig.return_annotation)
        impl_ret = _norm_annotation(impl_sig.return_annotation)
        if not _compatible_return(proto_ret, impl_ret):
            findings.append(
                f"{who}.{name}: return annotation `{impl_ret}` does not "
                f"satisfy the protocol's `{proto_ret}`")
        if impl_ret is None and not inspect.getdoc(impl_fn) \
                and proto_sig.return_annotation is not inspect.Signature.empty:
            findings.append(
                f"{who}.{name}: no return annotation and no docstring — the "
                f"return contract (protocol: `{proto_ret}`) must be stated "
                f"on the override")
    return findings


def _diff_signature(who: str, name: str, proto: inspect.Signature,
                    impl: inspect.Signature) -> list[str]:
    findings: list[str] = []
    pp = [p for p in proto.parameters.values() if p.name != "self"]
    ip = [p for p in impl.parameters.values() if p.name != "self"]
    for idx, p in enumerate(pp):
        if idx >= len(ip):
            findings.append(f"{who}.{name}: missing parameter `{p.name}` "
                            f"(protocol position {idx + 1})")
            continue
        q = ip[idx]
        if q.kind in (inspect.Parameter.VAR_POSITIONAL,
                      inspect.Parameter.VAR_KEYWORD):
            break  # *args/**kwargs absorbs the rest
        if q.name != p.name:
            findings.append(
                f"{who}.{name}: parameter {idx + 1} is `{q.name}`, protocol "
                f"says `{p.name}` — the orchestrator calls positionally and "
                f"keyword callers would break")
        if p.default is not inspect.Parameter.empty \
                and q.default is inspect.Parameter.empty:
            findings.append(
                f"{who}.{name}: parameter `{p.name}` drops the protocol's "
                f"default ({p.default!r})")
    for q in ip[len(pp):]:
        if q.kind in (inspect.Parameter.VAR_POSITIONAL,
                      inspect.Parameter.VAR_KEYWORD):
            continue
        if q.default is inspect.Parameter.empty \
                and q.kind is not inspect.Parameter.KEYWORD_ONLY:
            findings.append(
                f"{who}.{name}: extra required parameter `{q.name}` — the "
                f"orchestrator will never pass it; give it a default")
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    from repro.core.orchestrator import ExecutionBackend
    from repro.engine.backends import EngineBackend, SimBackend

    failed = 0
    for cls in (SimBackend, EngineBackend):
        findings = check_backend(cls, ExecutionBackend)
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"{cls.__name__}: {status}")
        for f in findings:
            print(f"  - {f}")
        failed += len(findings)
    return min(failed, 125)


if __name__ == "__main__":
    sys.exit(main())
